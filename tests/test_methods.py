"""QuantMethod registry tests: golden equivalence with the pre-refactor
string-dispatch path, serve/core preparation convergence, third-party
registration, and prepared-artifact round-trips."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import METHODS, ModelConfig, QuantConfig
from repro.core import hadamard, methods, quant, rrs, smooth
from repro.core.methods import PreparedLinear


# ---------------------------------------------------------------------------
# frozen pre-refactor reference (verbatim semantics of the old
# core/rrs.py string-dispatch quantized_matmul + prepare_weight)
# ---------------------------------------------------------------------------

def _ref_prepare_weight(w, cfg, sq_scale=None, calib_x=None):
    rotated = False
    block = 0
    if cfg.uses_rotation:
        block = hadamard.pick_rotate_block(w.shape[-1], cfg.rotate_block)
        w = hadamard.rotate_weight_in(w, block=block)
        rotated = True
    if cfg.method == "smoothquant" and sq_scale is None:
        from repro.core import smoothquant as sq_mod
        calib = calib_x if calib_x is not None else jnp.ones_like(w[:1])
        sq_scale = sq_mod.smoothquant_scales(calib, w)
    if cfg.method == "smoothquant" and sq_scale is not None:
        w = w * sq_scale[None, :]
    if not cfg.quantize_weights:
        return w, rotated, block, sq_scale
    if cfg.w_quantizer == "gptq" and calib_x is not None:
        from repro.core import gptq
        if rotated:
            calib_x = hadamard.rotate(calib_x, block=block)
        if cfg.method == "smoothquant" and sq_scale is not None:
            calib_x = calib_x / sq_scale
        w_dq = gptq.gptq_fakequant(w, calib_x, cfg.w_bits)
    else:
        w_dq = quant.fake_quant_per_channel(w, cfg.w_bits, axis=-1)
    return w_dq, rotated, block, sq_scale


def _ref_quantized_matmul(x, pw, cfg):
    w, rotated, block, sq_scale = pw
    if cfg.method == "none" or not cfg.quantize_acts:
        if cfg.method in ("quarot", "rrs") and rotated:
            x = hadamard.rotate(x, block=block)
        return x @ w.T.astype(x.dtype)
    if cfg.method in ("rtn", "gptq"):
        x_q = quant.fake_quant_per_channel(x, cfg.a_bits, axis=-1)
        return x_q @ w.T.astype(x.dtype)
    if cfg.method == "smoothquant":
        if sq_scale is not None:
            x = x / sq_scale.astype(x.dtype)
        x_q = quant.fake_quant_per_channel(x, cfg.a_bits, axis=-1)
        return x_q @ w.T.astype(x.dtype)
    if cfg.method == "rs":
        return smooth.rs_gemm_fakequant(
            x, w, cfg.a_bits, 16, group=cfg.group_size,
            reorder=cfg.reorder, w_q=w)
    if cfg.method == "quarot":
        x_rot = hadamard.rotate(x, block=block)
        x_q = quant.fake_quant_per_channel(x_rot, cfg.a_bits, axis=-1)
        return x_q @ w.T.astype(x.dtype)
    if cfg.method == "rrs":
        x_rot = hadamard.rotate(x, block=block)
        return smooth.rs_gemm_fakequant(
            x_rot, w, cfg.a_bits, 16, group=cfg.group_size,
            reorder=cfg.reorder, w_q=w)
    raise ValueError(cfg.method)


def _fixed_inputs(n=32, m=64, k=256):
    rng = np.random.default_rng(42)
    x = jnp.asarray(rng.standard_normal((n, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((m, k)) * 0.05, jnp.float32)
    return x, w


# ---------------------------------------------------------------------------
# registry coverage + golden equivalence
# ---------------------------------------------------------------------------

def test_registry_covers_all_builtin_methods():
    for m in METHODS:
        assert m in methods.available_methods()
        inst = methods.get_method(m)
        cfg = QuantConfig(4, 4, method=m)
        assert cfg.uses_rotation == inst.uses_rotation
        assert cfg.uses_runtime_smooth == inst.uses_runtime_smooth


@pytest.mark.parametrize("method", METHODS)
def test_apply_bitwise_matches_prerefactor_dispatch(method):
    """QuantMethod.apply must be bit-identical to the old quantized_matmul
    on fixed inputs (A4W4, group=128, RTN weights)."""
    x, w = _fixed_inputs()
    cfg = QuantConfig(4, 4, method=method, group_size=128,
                      w_quantizer="rtn")
    y_ref = _ref_quantized_matmul(x, _ref_prepare_weight(w, cfg), cfg)
    y_new = rrs.quantized_matmul(x, rrs.prepare_weight(w, cfg), cfg)
    assert np.array_equal(np.asarray(y_ref), np.asarray(y_new)), method


@pytest.mark.parametrize("method", ["rtn", "quarot", "rrs"])
def test_weight_only_bitwise_matches_prerefactor(method):
    x, w = _fixed_inputs()
    cfg = QuantConfig(16, 4, method=method, group_size=128)
    y_ref = _ref_quantized_matmul(x, _ref_prepare_weight(w, cfg), cfg)
    y_new = rrs.quantized_matmul(x, rrs.prepare_weight(w, cfg), cfg)
    assert np.array_equal(np.asarray(y_ref), np.asarray(y_new)), method


@pytest.mark.parametrize("method", ["smoothquant", "rrs"])
def test_calibrated_prepare_bitwise_matches_prerefactor(method):
    """GPTQ weights + (for smoothquant) calibrated scale merge."""
    x, w = _fixed_inputs()
    cfg = QuantConfig(4, 4, method=method, group_size=128,
                      w_quantizer="gptq")
    calib = x[:16]
    y_ref = _ref_quantized_matmul(
        x, _ref_prepare_weight(w, cfg, calib_x=calib), cfg)
    y_new = rrs.quantized_matmul(
        x, rrs.prepare_weight(w, cfg, calib_x=calib), cfg)
    assert np.array_equal(np.asarray(y_ref), np.asarray(y_new)), method


def test_qlinear_unprepared_matches_core_path():
    """models.layers.qlinear (inline offline half) == core prepare+apply."""
    from repro.models.layers import qlinear
    x, w = _fixed_inputs()
    for method in ("rtn", "rs", "quarot", "rrs"):
        cfg = QuantConfig(4, 4, method=method, group_size=128)
        y_l = qlinear(x, w, cfg)
        y_c = rrs.quantized_matmul(x, rrs.prepare_weight(w, cfg), cfg)
        assert np.array_equal(np.asarray(y_l), np.asarray(y_c)), method


# ---------------------------------------------------------------------------
# serve-path convergence (regression: prepare_params used to skip GPTQ
# and SmoothQuant scale merging that core prepare_weight performs)
# ---------------------------------------------------------------------------

MODEL = ModelConfig(name="prep", family="dense", num_layers=2, d_model=64,
                    num_heads=4, num_kv_heads=2, d_ff=192, vocab_size=260,
                    max_seq_len=128)


@pytest.fixture(scope="module")
def dense_params():
    from repro.models import build_model
    model = build_model(MODEL)
    params, _ = model.init(jax.random.PRNGKey(0))
    return model, params


@pytest.mark.parametrize("method,wq", [("rrs", "rtn"), ("rrs", "gptq"),
                                       ("smoothquant", "rtn"),
                                       ("quarot", "rtn")])
def test_prepare_params_matches_prepare_weight_per_leaf(dense_params,
                                                        method, wq):
    from repro.serve.prepare import QUANT_WEIGHTS, prepare_params
    _, params = dense_params
    qcfg = QuantConfig(4, 4, method=method, group_size=32,
                       w_quantizer=wq)
    rng = np.random.default_rng(7)
    calib = jnp.asarray(rng.standard_normal((16, MODEL.d_model)),
                        jnp.float32)
    prep = prepare_params(params, qcfg, calib=calib)

    flat_raw = jax.tree_util.tree_flatten_with_path(params)[0]
    flat_prep = {tuple(str(k) for k in path): leaf for path, leaf in
                 jax.tree_util.tree_flatten_with_path(
                     prep, is_leaf=methods.is_prepared)[0]
                 if methods.is_prepared(leaf)}
    checked = 0
    for path, leaf in flat_raw:
        name = str(getattr(path[-1], "key", path[-1]))
        if name not in QUANT_WEIGHTS or leaf.ndim < 2:
            continue
        key = tuple(str(k) for k in path)
        assert key in flat_prep, key
        got = flat_prep[key]
        c = calib if leaf.shape[-1] == MODEL.d_model else None
        if leaf.ndim == 2:
            want = rrs.prepare_weight(leaf, qcfg, calib_x=c)
            assert np.array_equal(np.asarray(got.w_dq),
                                  np.asarray(want.w_dq)), key
            if want.sq_scale is not None:
                assert np.array_equal(np.asarray(got.sq_scale),
                                      np.asarray(want.sq_scale)), key
        else:
            for i in range(leaf.shape[0]):
                want = rrs.prepare_weight(leaf[i], qcfg, calib_x=c)
                assert np.array_equal(np.asarray(got.w_dq[i]),
                                      np.asarray(want.w_dq)), (key, i)
        checked += 1
    assert checked >= 4  # wq/wk/wv/wo + mlp stacks


# ---------------------------------------------------------------------------
# third-party method registration — no dispatch-site edits
# ---------------------------------------------------------------------------

@methods.register_method("toy_pertensor")
class ToyPerTensor(methods.QuantMethod):
    """Per-tensor activation quant — deliberately NOT a builtin scheme."""

    def _apply_quant(self, x, prepared, cfg):
        x_q = quant.fake_quant_per_tensor(x, cfg.a_bits)
        return x_q @ prepared.w_dq.T.astype(x.dtype)


def test_registered_toy_method_through_qlinear():
    from repro.models.layers import qlinear
    x, w = _fixed_inputs()
    cfg = QuantConfig(8, 8, method="toy_pertensor")  # validates directly
    y = qlinear(x, w, cfg)
    y0 = x @ w.T
    rel = float(jnp.linalg.norm(y - y0) / jnp.linalg.norm(y0))
    assert rel < 0.05 and not bool(jnp.any(jnp.isnan(y)))
    # and through the one-shot core façade
    y2 = rrs.rrs_linear(x, w, cfg)
    assert np.array_equal(np.asarray(y), np.asarray(y2))


def test_registered_toy_method_through_serving_engine(dense_params):
    from repro.serve.engine import ServingEngine
    model, params = dense_params
    qcfg = QuantConfig(8, 8, method="toy_pertensor")
    eng = ServingEngine(model, params, qcfg, max_batch=2, max_len=64)
    eng.submit("the quick brown", max_new_tokens=6)
    done = eng.run()
    assert len(done) == 1 and len(done[0].out_tokens) >= 1
    assert methods.tree_has_prepared(eng.params)


# ---------------------------------------------------------------------------
# prepared-artifact round trip
# ---------------------------------------------------------------------------

def test_save_load_prepared_roundtrip_decode_identical(dense_params,
                                                       tmp_path):
    from repro.serve.prepare import (load_prepared, prepare_params,
                                     save_prepared)
    model, params = dense_params
    qcfg = QuantConfig(4, 4, 4, method="rrs", group_size=32,
                       w_quantizer="rtn")
    prep = prepare_params(params, qcfg)
    path = save_prepared(str(tmp_path / "art"), prep, qcfg)
    prep2, qcfg2 = load_prepared(path)
    assert qcfg2 == qcfg

    tokens = jnp.asarray([[1, 7, 42, 9]], jnp.int32)
    cache, _ = model.init_cache(1, 32)
    logits_a, cache_a = model.step(params=prep, tokens=tokens,
                                   cache=cache, qcfg=qcfg, prepared=True)
    cache, _ = model.init_cache(1, 32)
    logits_b, cache_b = model.step(params=prep2, tokens=tokens,
                                   cache=cache, qcfg=qcfg2, prepared=True)
    assert np.array_equal(np.asarray(logits_a), np.asarray(logits_b))
    # one decode step after prefill, also identical
    nxt = jnp.argmax(logits_a[:, -1:], -1).astype(jnp.int32)
    d_a, _ = model.step(params=prep, tokens=nxt, cache=cache_a,
                        qcfg=qcfg, prepared=True)
    d_b, _ = model.step(params=prep2, tokens=nxt, cache=cache_b,
                        qcfg=qcfg2, prepared=True)
    assert np.array_equal(np.asarray(d_a), np.asarray(d_b))


def test_from_artifact_engine_matches_in_memory(dense_params, tmp_path):
    from repro.serve.engine import ServingEngine
    from repro.serve.prepare import save_prepared
    model, params = dense_params
    qcfg = QuantConfig(4, 4, 4, method="rrs", group_size=32)
    eng = ServingEngine(model, params, qcfg, max_batch=2, max_len=64)
    eng.submit("hello there fox", max_new_tokens=6)
    done = eng.run()
    path = save_prepared(str(tmp_path / "art"), eng.params, qcfg)
    eng2 = ServingEngine.from_artifact(model, path, max_batch=2,
                                       max_len=64)
    eng2.submit("hello there fox", max_new_tokens=6)
    done2 = eng2.run()
    assert done[0].out_tokens == done2[0].out_tokens


# ---------------------------------------------------------------------------
# kernel exec path behind the same apply seam
# ---------------------------------------------------------------------------

def test_kernel_exec_path_selected_by_config():
    x, w = _fixed_inputs(n=32, m=128, k=256)
    cfg = QuantConfig(4, 4, method="rrs", group_size=128,
                      exec_path="kernel")
    pl = rrs.prepare_weight(w, cfg)
    assert pl.w_packed is not None and pl.w_packed.shape == (128, 128)
    assert pl.w_scale is not None
    y_k = rrs.quantized_matmul(x, pl, cfg)
    y0 = x @ w.T
    rel = float(jnp.linalg.norm(y_k - y0) / jnp.linalg.norm(y0))
    assert rel < 0.5 and not bool(jnp.any(jnp.isnan(y_k)))
    # fake path from the same config minus exec_path stays the reference
    cfg_f = QuantConfig(4, 4, method="rrs", group_size=128)
    y_f = rrs.quantized_matmul(x, rrs.prepare_weight(w, cfg_f), cfg_f)
    rel_kf = float(jnp.linalg.norm(y_k - y_f) / jnp.linalg.norm(y_f))
    assert rel_kf < 0.2  # same pipeline, integer vs QDQ rounding only


def test_prepared_linear_survives_scan_and_jit():
    x, w = _fixed_inputs()
    cfg = QuantConfig(4, 4, method="rrs", group_size=128)
    from repro.serve.prepare import _prepare_stacked
    stacked = _prepare_stacked(methods.get_method("rrs"),
                               jnp.stack([w, w * 0.5]), cfg, None)
    assert isinstance(stacked, PreparedLinear)
    assert stacked.w_dq.shape == (2, 64, 256)

    @jax.jit
    def run(xx, pls):
        def body(c, pl):
            return c, rrs.quantized_matmul(xx, pl, cfg)
        return jax.lax.scan(body, 0, pls)[1]

    ys = run(x, stacked)
    assert ys.shape == (2, 32, 64)
    assert not bool(jnp.any(jnp.isnan(ys)))
