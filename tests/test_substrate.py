"""Substrate tests: data pipeline, optimizers, schedules, gradient
compression, checkpointing, trainer fault tolerance, serving engine."""
import math
import os
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig, QuantConfig, TrainConfig
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models import build_model
from repro.optim import grad_compress, optimizers
from repro.train.trainer import Trainer

TINY = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                   num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=260,
                   max_seq_len=256)


# --------------------------- data ---------------------------------------

def test_pipeline_deterministic_and_disjoint_eval():
    dc = DataConfig(seq_len=64, global_batch=4)
    p = TokenPipeline(dc)
    assert (p.get_batch(7)["tokens"] == p.get_batch(7)["tokens"]).all()
    train = p.get_batch(0)["tokens"]
    ev = next(iter(p.eval_batches(1)))["tokens"]
    assert not (train == ev).all()


def test_pipeline_vocab_clamp():
    dc = DataConfig(seq_len=16, global_batch=2, vocab_size=100)
    toks = TokenPipeline(dc).get_batch(0)["tokens"]
    assert toks.max() < 100


# --------------------------- optim --------------------------------------

def test_adamw_first_step_magnitude():
    tc = TrainConfig(learning_rate=1e-2, warmup_steps=1, total_steps=10,
                     weight_decay=0.0, schedule="const")
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), 0.5)}
    st = optimizers.init_optimizer(tc, params)
    new_p, st2, lr = optimizers.apply_optimizer(tc, grads, st, params)
    # adam first step ≈ -lr * sign(g)
    assert np.allclose(np.asarray(new_p["w"]), 1.0 - 1e-2, atol=1e-3)


def test_adafactor_shapes_and_update():
    tc = TrainConfig(optimizer="adafactor", learning_rate=1e-2,
                     warmup_steps=1, total_steps=10, weight_decay=0.0)
    params = {"w": jnp.ones((8, 16)), "b": jnp.ones((8,))}
    st = optimizers.init_optimizer(tc, params)
    assert st.vr["w"].shape == (8,) and st.vc["w"].shape == (16,)
    grads = jax.tree.map(lambda p: 0.1 * jnp.ones_like(p), params)
    new_p, st2, _ = optimizers.apply_optimizer(tc, grads, st, params)
    assert float(jnp.max(new_p["w"])) < 1.0


def test_wsd_schedule_shape():
    tc = TrainConfig(schedule="wsd", learning_rate=1.0, warmup_steps=10,
                     total_steps=100, wsd_stable_frac=0.8)
    s = optimizers.make_schedule(tc)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert abs(float(s(50)) - 1.0) < 1e-6          # stable plateau
    assert float(s(95)) < 0.6                       # decay tail
    assert float(s(100)) < 0.05


def test_grad_clip():
    tree = {"a": jnp.full((10,), 10.0)}
    clipped, norm = optimizers.clip_by_global_norm(tree, 1.0)
    assert abs(float(optimizers.global_norm(clipped)) - 1.0) < 1e-5


def test_ef_compression_unbiased_over_steps():
    """Error feedback: accumulated compressed grads converge to the true
    sum (residual carries the rounding error)."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((1000,)) * 0.01, jnp.float32)
    res = None
    total = jnp.zeros_like(g)
    for _ in range(50):
        gq, res = grad_compress.ef_compress_tree({"g": g},
                                                 res if res is None
                                                 else res)
        total = total + gq["g"]
    ref = g * 50
    rel = float(jnp.linalg.norm(total - ref) / jnp.linalg.norm(ref))
    assert rel < 5e-3, rel


# --------------------------- trainer / fault tolerance -------------------

def test_trainer_divergence_rollback():
    """A poisoned step (NaN loss) rolls back to the last checkpoint and
    skips the bad batch."""
    model = build_model(TINY)
    tc = TrainConfig(total_steps=12, warmup_steps=2, learning_rate=1e-3)
    dc = DataConfig(seq_len=32, global_batch=4, vocab_size=260)
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(model, tc, dc, d, ckpt_every=5)
        base_step = tr._step_fn
        calls = {"n": 0}

        def poisoned(state, batch):
            calls["n"] += 1
            state, metrics = base_step(state, batch)
            if calls["n"] == 7:
                metrics = dict(metrics, loss=jnp.float32(float("nan")))
            return state, metrics

        tr._step_fn = poisoned
        rep = tr.run()
        assert rep.rollbacks == 1
        assert rep.steps_run >= 10
        assert math.isfinite(rep.final_loss)


def test_trainer_straggler_flag():
    model = build_model(TINY)
    tc = TrainConfig(total_steps=8, warmup_steps=2, learning_rate=1e-3)
    dc = DataConfig(seq_len=32, global_batch=4, vocab_size=260)
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(model, tc, dc, d, ckpt_every=100,
                     straggler_factor=3.0)
        base_step = tr._step_fn
        calls = {"n": 0}

        def slow(state, batch):
            calls["n"] += 1
            if calls["n"] == 6:
                time.sleep(1.0)          # injected straggler
            return base_step(state, batch)

        tr._step_fn = slow
        rep = tr.run()
        assert 5 in rep.straggler_flags  # step index 5 == 6th call


# --------------------------- serving ------------------------------------

def test_engine_wave_batching_and_eos():
    model = build_model(TINY)
    params, _ = model.init(jax.random.PRNGKey(0))
    from repro.serve.engine import ServingEngine
    qcfg = QuantConfig(4, 4, 4, method="rrs", group_size=32)
    eng = ServingEngine(model, params, qcfg, max_batch=2, max_len=128)
    for i in range(5):
        eng.submit("abcdef", max_new_tokens=6)
    done = eng.run()
    assert len(done) == 5
    assert all(1 <= len(r.out_tokens) <= 6 for r in done)


def test_kv_cache_quantization_close_to_fp():
    model = build_model(TINY)
    params, _ = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, 260)
    outs = {}
    for kv_bits in (16, 4):
        qcfg = QuantConfig(16, 16, kv_bits, method="rrs" if kv_bits < 16
                           else "none")
        cache, _ = model.init_cache(2, 64)
        lp, cache = model.step(params, tokens, cache, qcfg)
        ld, _ = model.step(params, jnp.argmax(lp[:, -1:], -1), cache, qcfg)
        outs[kv_bits] = ld
    rel = float(jnp.linalg.norm((outs[4] - outs[16]).astype(jnp.float32))
                / jnp.linalg.norm(outs[16].astype(jnp.float32)))
    assert rel < 0.25, rel
