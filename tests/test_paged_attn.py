"""Block-table paged-attention decode kernel (kernels/paged_attn):
interpret-mode parity against the jnp oracle across block sizes, at-rest
storages (int8 / packed-int4), GQA widths and mixed-progress rows; the
paged_gather clamp-to-0 poison pin; the jaxpr no-gathered-intermediate
acceptance check; and the engine-level token-identity chain for the
at-rest rrs a4w4kv4 path.

Numerics contract (see kernels/paged_attn.py): the kernel and the oracle
share the dequant / online-update / finalize helpers bit-for-bit, so
kernel-vs-oracle is EXACT under jit-vs-jit.  The kernel vs the *dense*
softmax (gather path / dense cache) is only ever argmax-stable, never
bitwise — the engine chain below pins token identity through the
paged-gather middleman on the f32-compute model (bf16 logit ulp ≈ the
online-vs-dense drift, so bf16 near-ties flip; a4 smooth-scale rounding
makes any drift chaotic — see tests/test_paging.py's pin docstrings).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig, QuantConfig
from repro.core import kvquant, quant
from repro.kernels import paged_attn as kpa
from repro.kernels import ref as kref
from repro.models import build_model, layers
from repro.serve.engine import ServingEngine

TINY32 = ModelConfig(name="t32", family="dense", num_layers=2, d_model=64,
                     num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=260,
                     max_seq_len=256, dtype="float32")


# ---------------------------------------------------------------------------
# kernel vs oracle (interpret mode, bit-exact)
# ---------------------------------------------------------------------------

def _mk_case(b, mb, bs, kvh, rep, d, storage, group, seed=0):
    """Random full arena + shuffled tables + mixed-progress qpos:
    row 0 frozen (-1: no visible key), row 1 freshly admitted (one
    token), row 2 mid-decode (partial tail block), the rest full."""
    rng = np.random.default_rng(seed)
    nb = b * mb
    kf = rng.standard_normal((nb, bs, kvh, d)).astype(np.float32)
    vf = rng.standard_normal((nb, bs, kvh, d)).astype(np.float32)
    q = jnp.asarray(rng.standard_normal((b, kvh, rep, d)), jnp.bfloat16)
    tables = jnp.asarray(rng.permutation(nb).reshape(b, mb), jnp.int32)
    qpos = np.full((b,), mb * bs - 1, np.int64)
    qpos[0] = -1
    if b > 1:
        qpos[1] = 0
    if b > 2:
        qpos[2] = (mb // 2) * bs + bs // 2       # inside a tail block
    qpos = jnp.asarray(qpos, jnp.int32)
    if storage == "fake":
        return (q, jnp.asarray(kf, jnp.bfloat16), jnp.asarray(vf, jnp.bfloat16),
                None, None, tables, qpos, 4)
    bits = 8 if storage == "int8" else 4
    kq = kvquant.kv_quantize(jnp.asarray(kf), bits, group)
    vq = kvquant.kv_quantize(jnp.asarray(vf), bits, group)
    kc, vc = kq.codes, vq.codes
    if storage == "int4":
        kc, vc = quant.pack_int4(kc), quant.pack_int4(vc)
    return q, kc, vc, kq.scales, vq.scales, tables, qpos, bits


@pytest.mark.parametrize("storage,bs,rep,group", [
    ("fake", 4, 2, 32),       # QDQ read path, small blocks
    ("fake", 16, 1, 32),      # rep=1 (MHA-shaped), bigger blocks
    ("int8", 4, 2, 16),       # at-rest int8, TWO scale groups per head
    ("int8", 8, 1, 32),
    ("int4", 4, 2, 32),       # packed nibbles (Dc = D//2)
    ("int4", 8, 4, 16),       # wide GQA + multi-group scales
])
def test_kernel_matches_oracle_bitexact(storage, bs, rep, group):
    b, mb, kvh, d = 4, 6, 2, 32
    q, k, v, ks, vs, tables, qpos, bits = _mk_case(
        b, mb, bs, kvh, rep, d, storage, group)
    kern = jax.jit(lambda *a: kpa.paged_decode_attn(
        a[0], a[1], a[2], a[5], a[6], k_scale=a[3], v_scale=a[4],
        kv_bits=bits, kv_group=group, x_dtype=jnp.bfloat16))
    orac = jax.jit(lambda *a: kref.paged_attn_decode_ref(
        a[0], a[1], a[2], a[5], a[6], a[3], a[4],
        kv_bits=bits, kv_group=group, x_dtype=jnp.bfloat16))
    args = (q, k, v, ks, vs, tables, qpos)
    y, yr = kern(*args), orac(*args)
    assert y.shape == (b, kvh, rep, d)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
    # rows with no visible key output exactly 0 (the empty-row contract
    # that keeps frozen slots out of the batch-global smooth scales)
    assert bool(jnp.all(y[0] == 0))


def test_kernel_sliding_window_matches_oracle():
    b, mb, bs, kvh, rep, d = 3, 6, 4, 2, 2, 32
    q, k, v, ks, vs, tables, qpos, bits = _mk_case(
        b, mb, bs, kvh, rep, d, "fake", 32)
    for window in (5, 16):
        kern = jax.jit(lambda qq, kk, vv, tt, pp, w=window:
                       kpa.paged_decode_attn(qq, kk, vv, tt, pp,
                                             kv_bits=16, window=w,
                                             x_dtype=jnp.bfloat16))
        orac = jax.jit(lambda qq, kk, vv, tt, pp, w=window:
                       kref.paged_attn_decode_ref(qq, kk, vv, tt, pp,
                                                  kv_bits=16, window=w,
                                                  x_dtype=jnp.bfloat16))
        np.testing.assert_array_equal(
            np.asarray(kern(q, k, v, tables, qpos)),
            np.asarray(orac(q, k, v, tables, qpos)))


# ---------------------------------------------------------------------------
# paged_gather: masked-invisible is not masked-unread (satellite pin)
# ---------------------------------------------------------------------------

def test_paged_gather_unallocated_reads_block0_not_last():
    """Unallocated table entries (-1) are still READ by the dense gather;
    a raw -1 would wrap (jnp negative indexing) to the arena's LAST
    block — aliasing whichever live row owns it.  kvquant.paged_gather
    clamps to block 0 instead: poison the last block and pin that the
    -1 slots come back as block 0's contents, never the poison.  (The
    poison is finite on purpose: the mask only makes these rows
    invisible downstream via 0-weight, which would NOT scrub NaN/Inf.)"""
    nb, bs, kvh, d = 5, 4, 2, 8
    arena = jnp.arange(nb * bs * kvh * d, dtype=jnp.float32).reshape(
        nb, bs, kvh, d)
    poison = 1e30
    arena = arena.at[-1].set(poison)
    tables = jnp.array([[1, -1, -1], [2, 3, -1]], jnp.int32)
    out = kvquant.paged_gather(arena, tables)       # (B, mb*bs, kvh, d)
    out = np.asarray(out.reshape(2, 3, bs, kvh, d))
    np.testing.assert_array_equal(out[0, 1], np.asarray(arena[0]))
    np.testing.assert_array_equal(out[0, 2], np.asarray(arena[0]))
    np.testing.assert_array_equal(out[1, 2], np.asarray(arena[0]))
    assert not np.any(out == poison)
    # allocated slots still resolve through the table
    np.testing.assert_array_equal(out[1, 1], np.asarray(arena[3]))


def test_kernel_never_reads_unallocated_blocks():
    """The kernel's index map clamps past-the-end grid steps to the
    row's last VISIBLE block, so — unlike the gather — unallocated
    slots are never fetched at all: poisoning every block outside the
    rows' chains with NaN leaves the output finite and oracle-exact
    (the oracle reads clamped block 0, which is inside a chain here,
    and masks it)."""
    b, mb, bs, kvh, rep, d = 2, 4, 4, 2, 2, 32
    rng = np.random.default_rng(3)
    nb = b * mb
    kf = rng.standard_normal((nb, bs, kvh, d)).astype(np.float32)
    vf = rng.standard_normal((nb, bs, kvh, d)).astype(np.float32)
    # rows own blocks 0..2 and 4..6; blocks 3 and 7 are NaN-poisoned
    kf[3] = kf[7] = np.nan
    vf[3] = vf[7] = np.nan
    tables = jnp.array([[0, 1, 2, -1], [4, 5, 6, -1]], jnp.int32)
    qpos = jnp.array([3 * bs - 1, 2 * bs + 1], jnp.int32)
    q = jnp.asarray(rng.standard_normal((b, kvh, rep, d)), jnp.bfloat16)
    k, v = jnp.asarray(kf, jnp.bfloat16), jnp.asarray(vf, jnp.bfloat16)
    kern = jax.jit(lambda *a: kpa.paged_decode_attn(
        *a, kv_bits=16, x_dtype=jnp.bfloat16))
    orac = jax.jit(lambda *a: kref.paged_attn_decode_ref(
        *a, kv_bits=16, x_dtype=jnp.bfloat16))
    y = kern(q, k, v, tables, qpos)
    assert bool(jnp.all(jnp.isfinite(y.astype(jnp.float32))))
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(orac(q, k, v, tables, qpos)))


# ---------------------------------------------------------------------------
# the s == 1 decode step lowers to the kernel (acceptance: jaxpr check)
# ---------------------------------------------------------------------------

def test_decode_jaxpr_has_no_gathered_intermediate():
    """Under the kernel impl the s == 1 paged step's jaxpr contains NO
    ``(B, max_blocks·bs, ...)`` logical-view intermediate — the gather
    never happens, not merely gets masked; the gather impl's jaxpr DOES
    contain it (differential control)."""
    model = build_model(TINY32)
    params, _ = model.init(jax.random.PRNGKey(0))
    qcfg = QuantConfig()
    b, max_len, bs = 2, 32, 4
    nb = b * (max_len // bs)
    cache, _ = model.init_cache(b, max_len, paged=(nb, bs))
    toks = jnp.ones((b, 1), jnp.int32)
    hd = TINY32.resolved_head_dim
    view_dims = f"{b},{max_len},{TINY32.num_kv_heads},{hd}]"
    jxp = {}
    try:
        for impl in ("kernel", "gather"):
            layers.set_paged_decode_impl(impl)
            jxp[impl] = str(jax.make_jaxpr(
                lambda p, t, c: model.step(p, t, c, qcfg))(
                    params, toks, cache))
    finally:
        layers.set_paged_decode_impl("kernel")
    assert view_dims in jxp["gather"]        # the control: gather builds it
    assert view_dims not in jxp["kernel"]
    assert "pallas_call" in jxp["kernel"] or "while" in jxp["kernel"]


# ---------------------------------------------------------------------------
# engine: at-rest packed-int4 token-identity chain (rrs a4w4kv4)
# ---------------------------------------------------------------------------

def test_engine_at_rest_int4_kernel_token_identical_to_gather():
    """rrs a4w4 + kv_storage="int8"/kv_bits=4 (the engine packs this to
    the int4 arena): greedy decode under the kernel impl is TOKEN-
    IDENTICAL to the gather impl on the f32-compute model.  Combined
    with test_paging.py's bitwise dense≡paged-gather pin this closes
    the dense ≡ paged-kernel chain for the at-rest quantized arena —
    the config the kernel's fused dequant prologue exists for."""
    qcfg = QuantConfig(4, 4, 4, method="rrs", group_size=32,
                       kv_storage="int8")
    model = build_model(TINY32)
    params, _ = model.init(jax.random.PRNGKey(0))
    prompts = ["abcdef", "ghijkl", "mnopqr"]
    outs = {}
    try:
        for impl in ("gather", "kernel"):
            layers.set_paged_decode_impl(impl)
            eng = ServingEngine(model, params, qcfg, max_batch=3,
                                max_len=64, cache="paged", block_size=8)
            assert eng.kv_storage_kind == "int4"   # packed at rest
            for i, p in enumerate(prompts):
                eng.submit(p, max_new_tokens=4 + 2 * i)
            done = sorted(eng.run(), key=lambda r: r.rid)
            assert len(done) == len(prompts)
            outs[impl] = [r.out_tokens for r in done]
    finally:
        layers.set_paged_decode_impl("kernel")
    assert outs["gather"] == outs["kernel"]
