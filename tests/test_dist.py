"""Distribution tests — run in a SUBPROCESS with 8 fake CPU devices so the
main pytest process keeps its single-device jax config."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, f"STDERR:\n{out.stderr[-3000:]}"
    return out.stdout


def test_sharded_train_step_matches_single_device():
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import ModelConfig, QuantConfig, TrainConfig
        from repro.data.pipeline import DataConfig, TokenPipeline
        from repro.dist import sharding as shd
        from repro.models import build_model
        from repro.train.train_step import init_train_state, make_train_step

        cfg = ModelConfig(name="t", family="dense", num_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=2,
                          d_ff=128, vocab_size=260, max_seq_len=256)
        model = build_model(cfg)
        tc = TrainConfig(total_steps=10, warmup_steps=2,
                         learning_rate=1e-3)
        dc = DataConfig(seq_len=64, global_batch=8, vocab_size=260)
        pipe = TokenPipeline(dc)
        batch = {k: jnp.asarray(v) for k, v in pipe.get_batch(0).items()}

        # single-device reference
        state, axes = init_train_state(model, tc, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(model, tc))
        _, m_ref = step(state, batch)

        # 2x4 mesh data x model
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = shd.make_rules("train")
        with shd.use_rules(mesh, rules):
            state2, _ = init_train_state(model, tc, jax.random.PRNGKey(0))
            step2 = jax.jit(make_train_step(model, tc))
            _, m_sh = step2(state2, batch)
        d = abs(float(m_ref["loss"]) - float(m_sh["loss"]))
        assert d < 2e-2, f"loss mismatch {d}"
        print("OK", float(m_ref["loss"]), float(m_sh["loss"]))
    """)
    assert "OK" in out


def test_moe_shard_map_matches_local():
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs.base import ModelConfig, MoEConfig, QuantConfig
        from repro.dist import sharding as shd
        from repro.models import moe as moe_mod

        cfg = ModelConfig(name="m", family="moe", num_layers=1, d_model=32,
                          num_heads=2, num_kv_heads=2, d_ff=64,
                          vocab_size=64,
                          moe=MoEConfig(num_experts=8, experts_per_token=2,
                                        expert_d_ff=32))
        p, _ = moe_mod.moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
        y_local, aux_local = moe_mod.moe_apply(p, x, cfg, QuantConfig(),
                                               False)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with shd.use_rules(mesh, shd.make_rules("train")):
            y_ep, aux_ep = jax.jit(
                lambda p, x: moe_mod.moe_apply(p, x, cfg, QuantConfig(),
                                               False))(p, x)
        # EP capacity differs (per-shard) => small drop differences ok
        rel = float(jnp.linalg.norm(y_ep - y_local)
                    / jnp.linalg.norm(y_local))
        assert rel < 0.35, rel
        # decode-style inference EP (experts over both axes)
        with shd.use_rules(mesh, shd.make_rules("decode")):
            y_inf, _ = jax.jit(
                lambda p, x: moe_mod.moe_apply(p, x, cfg, QuantConfig(),
                                               False))(p, x)
        rel2 = float(jnp.linalg.norm(y_inf - y_local)
                     / jnp.linalg.norm(y_local))
        assert rel2 < 0.35, rel2
        print("OK", rel, rel2)
    """)
    assert "OK" in out


def test_moe_prepared_expert_parallel():
    """PREPARED MoE serving on a mesh: expert leaves are PreparedLinear
    pytrees, so ``moe_apply``'s shard_map needs per-field in_specs (the
    old raw (E, M, K) spec did not match the artifact structure) — both
    the training/prefill EP dispatch and the decode-style inference EP
    must accept a prepared tree (closes the ROADMAP open item)."""
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs.base import ModelConfig, MoEConfig, QuantConfig
        from repro.dist import sharding as shd
        from repro.models import moe as moe_mod
        from repro.serve.prepare import prepare_params

        cfg = ModelConfig(name="m", family="moe", num_layers=1, d_model=32,
                          num_heads=2, num_kv_heads=2, d_ff=64,
                          vocab_size=64,
                          moe=MoEConfig(num_experts=8, experts_per_token=2,
                                        expert_d_ff=32))
        qcfg = QuantConfig(4, 4, method="rrs", group_size=16)
        p, _ = moe_mod.moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
        p = prepare_params(p, qcfg)          # stacked PreparedLinear leaves
        from repro.core.methods import PreparedLinear
        assert isinstance(p["w_gate"], PreparedLinear)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
        y_local, _ = moe_mod.moe_apply(p, x, cfg, qcfg, True)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with shd.use_rules(mesh, shd.make_rules("train")):
            y_ep, _ = jax.jit(
                lambda p, x: moe_mod.moe_apply(p, x, cfg, qcfg, True))(p, x)
        rel = float(jnp.linalg.norm(y_ep - y_local)
                    / jnp.linalg.norm(y_local))
        assert rel < 0.35, rel
        with shd.use_rules(mesh, shd.make_rules("decode")):
            y_inf, _ = jax.jit(
                lambda p, x: moe_mod.moe_apply(p, x, cfg, qcfg, True))(p, x)
        rel2 = float(jnp.linalg.norm(y_inf - y_local)
                     / jnp.linalg.norm(y_local))
        assert rel2 < 0.35, rel2
        print("OK", rel, rel2)
    """)
    assert "OK" in out


def test_pipeline_parallel_matches_sequential():
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.dist.pipeline_par import pipeline_forward, \\
            stack_for_stages

        mesh = jax.make_mesh((4,), ("pod",))
        L, d = 8, 32
        ws = jax.random.normal(jax.random.PRNGKey(0), (L, d, d)) * 0.2
        x = jax.random.normal(jax.random.PRNGKey(1), (16, d))

        def stage_fn(params, xx):
            def body(c, w):
                return jnp.tanh(c @ w), None
            out, _ = jax.lax.scan(body, xx, params)
            return out

        # sequential reference
        y_ref = stage_fn(ws, x)
        sp = stack_for_stages(ws, 4)
        y_pp = pipeline_forward(mesh, "pod", stage_fn, sp, x, n_micro=4)
        err = float(jnp.max(jnp.abs(y_pp - y_ref)))
        assert err < 1e-5, err
        print("OK", err)
    """)
    assert "OK" in out


def test_compressed_psum_accuracy_and_wire():
    out = run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.optim.grad_compress import compressed_psum

        mesh = jax.make_mesh((8,), ("data",))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 4096))

        def f(xs):
            return compressed_psum(xs, "data")

        y = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P("data"),
                                  out_specs=P("data"),
                                  check_vma=False))(x)
        ref = jnp.broadcast_to(jnp.sum(x, 0, keepdims=True), x.shape)
        rel = float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))
        assert rel < 2e-2, rel
        print("OK", rel)
    """)
    assert "OK" in out


def test_elastic_checkpoint_reshard():
    """Save on 8 devices (2x4 mesh), restore onto 1 device and onto a
    4x2 mesh — elastic restore."""
    out = run_with_devices("""
        import tempfile, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt import checkpoint as ck

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        w = jax.device_put(
            jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32),
            NamedSharding(mesh, P("data", "model")))
        tree = {"w": w, "step": jnp.ones(())}
        with tempfile.TemporaryDirectory() as d:
            path = ck.save(d + "/step_00000001", tree, 1)
            # restore replicated (1-device view)
            r1, _ = ck.restore(path, tree)
            assert np.allclose(np.asarray(r1["w"]), np.asarray(w))
            # restore onto a different mesh layout
            mesh2 = jax.make_mesh((4, 2), ("data", "model"))
            sh = {"w": NamedSharding(mesh2, P("model", "data")),
                  "step": NamedSharding(mesh2, P())}
            r2, _ = ck.restore(path, tree, shardings=sh)
            assert np.allclose(np.asarray(r2["w"]), np.asarray(w))
            assert r2["w"].sharding.spec == P("model", "data")
        print("OK")
    """)
    assert "OK" in out


def test_dryrun_tiny_mesh_end_to_end():
    """The dry-run machinery itself on a small mesh (cheap CI proxy for
    the 512-device run)."""
    out = run_with_devices("""
        import os
        import jax
        from repro.dist import sharding as shd
        from repro.launch import dryrun as dr
        from repro.launch.mesh import make_mesh
        import repro.launch.dryrun as D

        # monkeypatch the production mesh to 2x4 for this test
        import repro.launch.mesh as M
        M.make_production_mesh = lambda multi_pod=False: (
            jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
            if multi_pod else jax.make_mesh((2, 4), ("data", "model")))
        D.make_production_mesh = M.make_production_mesh
        rec = D.run_cell("smollm-135m", "decode_32k", multi_pod=False,
                         verbose=False)
        assert "error" not in rec and rec["t_mem"] > 0
        rec2 = D.run_cell("smollm-135m", "train_4k", multi_pod=True,
                          verbose=False)
        assert "error" not in rec2 and rec2["dominant"]
        print("OK", rec["dominant"], rec2["dominant"])
    """, n=8)
    assert "OK" in out
